"""Benchmark orchestrator — one module per paper table/figure + kernel
microbench + roofline report. Prints ``name,us_per_call,derived`` CSV.

``--help`` lists every registered figure; ``--only`` runs a subset:

    PYTHONPATH=src python benchmarks/run.py               # everything
    PYTHONPATH=src python benchmarks/run.py --only fig12 serving
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import traceback

# Self-locating (like scripts/bench_check.py): `python benchmarks/run.py`
# puts benchmarks/ — not the repo root — on sys.path.
_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (_REPO_ROOT, _REPO_ROOT / "src"):
    if str(_p) not in sys.path:
        sys.path.insert(0, str(_p))

#: Registered figures: CLI name -> (module name, one-line description).
FIGURES = {
    "fig1": ("fig1_design_points",
             "design points — PE counts/areas of every dataflow class"),
    "fig6": ("fig6_single_kernel",
             "single-kernel scheduling across heterogeneous clusters"),
    "fig8": ("fig8_hwdb",
             "hardware DB calibration (area/power per PE)"),
    "fig10": ("fig10_limited_bw",
              "speedups at HBM bandwidth vs homogeneous baselines"),
    "fig11": ("fig11_unlimited_bw",
              "speedups at unlimited bandwidth"),
    "fig12": ("fig12_many_kernel",
              "many-kernel policy x design sweep + online queueing + "
              "spatial-concurrency rows"),
    "fig13": ("fig13_dse",
              "DSE search wall time, AESPA-opt vs baselines, Pareto, "
              "co-DSE"),
    "kernel_micro": ("kernel_micro",
                     "Pallas kernel / expansion / scheduler microbench"),
    "roofline": ("roofline",
                 "roofline placement of every Table I workload"),
    "serving": ("serving_traffic",
                "ClusterServer staggered-trace replay per policy + "
                "claim/admission/overlap rows"),
    "fleet": ("fleet_traffic",
              "4-replica fleet replay of the 100x Table I trace with and "
              "without an injected replica death"),
}


def _parse_args(argv=None):
    listing = "\n".join(f"  {name:<13} {desc}"
                        for name, (_, desc) in FIGURES.items())
    ap = argparse.ArgumentParser(
        prog="benchmarks/run.py",
        description=__doc__.splitlines()[0],
        epilog="registered figures:\n" + listing,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--only", nargs="+", metavar="FIG", choices=sorted(FIGURES),
                    help="run only these figures (default: all)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="enable tracing (repro.obs) for the run and write "
                         "a Perfetto-loadable Chrome trace of every figure "
                         "executed to PATH")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress progress prints (stderr); the stdout "
                         "CSV contract is unaffected")
    return ap.parse_args(argv)


def main(argv=None) -> None:
    args = _parse_args(argv)
    names = list(FIGURES) if not args.only else list(args.only)

    import importlib

    from repro import obs
    from benchmarks.common import emit, log

    if args.quiet:
        obs.set_quiet(True)
    if args.trace_out:
        obs.TRACE.reset()
        obs.enable()

    print("name,us_per_call,derived")
    failed = 0
    for name in names:
        module_name, desc = FIGURES[name]
        log(f"[bench] {name}: {desc}")
        with obs.TRACE.span(f"figure:{name}", tid="bench", cat="bench"):
            try:
                mod = importlib.import_module(f"benchmarks.{module_name}")
                emit(mod.run())
            except Exception as e:  # noqa: BLE001
                failed += 1
                print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
                traceback.print_exc(file=sys.stderr)
    if args.trace_out:
        obs.disable()
        path = obs.TRACE.export_chrome_trace(args.trace_out)
        log(f"[bench] wrote Chrome trace: {path} "
            f"({len(obs.TRACE.events())} events, "
            f"{obs.TRACE.dropped} dropped)")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
