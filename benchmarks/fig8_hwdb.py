"""Fig 8/9 — HARD TACO hardware characterisation of the sub-accelerator
building blocks (per-PE area/power at 28 nm, Vitis initiation intervals).
These are the calibration constants embedded in core.hwdb; this benchmark
reports them plus the derived sanity identities the paper's Fig 1 implies.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core import hwdb


def run() -> List[Row]:
    rows: List[Row] = []
    for cls, p in hwdb.PROFILES.items():
        rows.append((
            f"fig8/{cls.value}", 0.0,
            f"area_um2_per_pe={p.area_mm2_per_pe * 1e6:.1f};"
            f"power_mw_per_pe={p.power_mw_per_pe:.2f};"
            f"ii={p.initiation_interval};fig1_pes={p.fig1_pes};"
            f"peak_tflops={hwdb.peak_tflops(p.fig1_pes):.2f}",
        ))
    rows.append((
        "fig8/hybrid", 0.0,
        f"area_um2_per_pe={hwdb.HYBRID_AREA_PER_PE * 1e6:.1f};"
        f"power_mw_per_pe={hwdb.HYBRID_POWER_PER_PE:.2f};"
        f"fig1_pes={hwdb.HYBRID_PES};peak_tflops={hwdb.HYBRID_TFLOPS:.2f}",
    ))
    from repro.formats.taxonomy import DataflowClass as D

    areas = {c: p.area_mm2_per_pe for c, p in hwdb.PROFILES.items()}
    rows.append((
        "fig8/sanity", 0.0,
        f"extensor_vs_tpu_area={areas[D.SPGEMM_INNER] / areas[D.GEMM]:.2f}x;"
        f"paper=~3x;budget_mm2={hwdb.COMPUTE_MM2}",
    ))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
